"""Config-driven decoder LM: init / train forward / prefill / decode.

Layer layout: the ``block_pattern`` tiles across ``n_layers``. Layers are
split into
    prefix  — first_k_dense MoE-exception layers (unrolled),
    groups  — scan over stacked repeats of one pattern period (keeps the HLO
              small: compile time and code size are O(pattern), not O(L)),
    suffix  — the non-divisible remainder (unrolled).
Params are plain nested dicts; stacked group leaves carry a leading repeat
dim. Sharding is by logical axis names resolved per-path (PARAM_RULES).
"""
from __future__ import annotations

import functools
import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.partition import aconstraint
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib


# ---------------------------------------------------------------------------
# config adapters
# ---------------------------------------------------------------------------
def attn_config(cfg: ArchConfig, kind: str) -> attn.AttnConfig:
    return attn.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.d_head, rope_theta=cfg.rope_theta, qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm,
        window=cfg.window if kind == "local_attn" else 0,
        q_block=cfg.q_block,
        q_lora_rank=cfg.q_lora_rank, kv_lora_rank=cfg.kv_lora_rank,
        qk_nope_head_dim=cfg.qk_nope_head_dim,
        qk_rope_head_dim=cfg.qk_rope_head_dim, v_head_dim=cfg.v_head_dim,
        rms_eps=cfg.rms_eps, kv_quant=cfg.kv_quant)


def ssm_config(cfg: ArchConfig) -> ssm_lib.SSMConfig:
    return ssm_lib.SSMConfig(d_model=cfg.d_model, d_state=cfg.ssm_state,
                             expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
                             chunk=cfg.ssm_chunk, conv_width=cfg.conv_width)


def rglru_config(cfg: ArchConfig) -> ssm_lib.RGLRUConfig:
    return ssm_lib.RGLRUConfig(d_model=cfg.d_model,
                               lru_width=cfg.lru_width or cfg.d_model,
                               conv_width=cfg.conv_width)


def moe_config(cfg: ArchConfig) -> moe_lib.MoEConfig:
    return moe_lib.MoEConfig(
        d_model=cfg.d_model, n_experts=cfg.n_experts, top_k=cfg.top_k,
        d_expert=cfg.d_expert, n_shared_experts=cfg.n_shared_experts,
        normalize_topk=cfg.normalize_topk,
        capacity_factor=cfg.capacity_factor)


def _ffn_kind(cfg: ArchConfig, layer_idx: int, mixer_kind: str) -> str:
    if mixer_kind == "ssd":
        return "none"
    if cfg.ffn == "moe":
        return "dense" if layer_idx < cfg.first_k_dense else "moe"
    return cfg.ffn  # swiglu | gelu


def _layer_plan(cfg: ArchConfig):
    """-> (prefix_idx, group_reps, suffix_idx). Groups start after prefix."""
    kinds = cfg.layer_kinds
    period = len(cfg.block_pattern)
    prefix_n = cfg.first_k_dense if cfg.ffn == "moe" else 0
    # align prefix up to a period boundary so groups are uniform
    prefix_n = -(-prefix_n // period) * period if prefix_n else 0
    rem = cfg.n_layers - prefix_n
    reps = rem // period
    suffix_n = rem - reps * period
    prefix = list(range(prefix_n))
    suffix = list(range(cfg.n_layers - suffix_n, cfg.n_layers))
    return prefix, reps, suffix, kinds


# ---------------------------------------------------------------------------
# per-layer init / forward / prefill / decode
# ---------------------------------------------------------------------------
def _layer_init(key, cfg: ArchConfig, kind: str, ffn_kind: str, dtype):
    k1, k2 = jax.random.split(key)
    p: dict[str, Any] = {"mixer_norm": L.rmsnorm_init(cfg.d_model, dtype)}
    if kind in ("attn", "local_attn"):
        p["mixer"] = attn.gqa_init(k1, attn_config(cfg, kind), dtype)
    elif kind == "mla":
        p["mixer"] = attn.mla_init(k1, attn_config(cfg, kind), dtype)
    elif kind == "ssd":
        p["mixer"] = ssm_lib.mamba2_init(k1, ssm_config(cfg), dtype)
    elif kind == "rglru":
        p["mixer"] = ssm_lib.rglru_block_init(k1, rglru_config(cfg), dtype)
    else:
        raise ValueError(kind)
    if ffn_kind != "none":
        p["ffn_norm"] = L.rmsnorm_init(cfg.d_model, dtype)
        if ffn_kind == "moe":
            p["ffn"] = moe_lib.moe_init(k2, moe_config(cfg), dtype)
        elif ffn_kind == "dense":
            p["ffn"] = L.swiglu_init(k2, cfg.d_model,
                                     cfg.dense_d_ff or cfg.d_ff, dtype)
        elif ffn_kind in ("swiglu", "geglu"):
            p["ffn"] = L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)
        elif ffn_kind == "gelu":
            p["ffn"] = L.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
        else:
            raise ValueError(ffn_kind)
    return p


def _moe_dispatch(pf, h, moe_cfg):
    """Pick the MoE implementation from the active partitioning rules:
    'shard_map_ep' (explicit all-to-all expert parallelism, §Perf B3) when
    an expert axis exists and the sequence divides it; else the
    single-program gspmd_sort path."""
    from repro.launch.partition import active_context
    ctx = active_context()
    if ctx is not None:
        mesh, rules = ctx
        expert_axes = rules.get("expert") or ()
        expert_axes = ((expert_axes,) if isinstance(expert_axes, str)
                       else tuple(expert_axes))
        if (rules.get("moe_impl") == "shard_map_ep"
                and len(expert_axes) == 1
                and h.shape[1] % mesh.shape[expert_axes[0]] == 0):
            from repro.models.moe_ep import moe_forward_ep
            return moe_forward_ep(pf, h, moe_cfg, mesh, rules)
    return moe_lib.moe_forward(pf, h, moe_cfg)


def _ffn_apply(p, x, cfg: ArchConfig, ffn_kind: str):
    aux = jnp.zeros((), jnp.float32)
    if ffn_kind == "none":
        return x, aux
    h = L.rmsnorm(p["ffn_norm"], x, cfg.rms_eps)
    if ffn_kind == "moe":
        h, metrics = _moe_dispatch(p["ffn"], h, moe_config(cfg))
        aux = metrics["moe_aux_total"]
    elif ffn_kind == "gelu":
        h = L.gelu_mlp(p["ffn"], h)
    elif ffn_kind == "geglu":
        h = L.geglu(p["ffn"], h)
    else:
        h = L.swiglu(p["ffn"], h)
    return x + h, aux


def _layer_forward(p, x, positions, cfg: ArchConfig, kind: str,
                   ffn_kind: str):
    h = L.rmsnorm(p["mixer_norm"], x, cfg.rms_eps)
    if kind in ("attn", "local_attn"):
        h = attn.gqa_forward(p["mixer"], h, positions, attn_config(cfg, kind))
    elif kind == "mla":
        h = attn.mla_forward(p["mixer"], h, positions, attn_config(cfg, kind))
    elif kind == "ssd":
        h = ssm_lib.mamba2_forward(p["mixer"], h, ssm_config(cfg))
    elif kind == "rglru":
        h = ssm_lib.rglru_block_forward(p["mixer"], h, rglru_config(cfg))
    x = x + h
    x = aconstraint(x, ("batch", "seq", "embed"))
    return _ffn_apply(p, x, cfg, ffn_kind)


def _layer_cache_init(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      dtype):
    if kind in ("attn", "local_attn"):
        return attn.gqa_init_cache(batch, max_len, attn_config(cfg, kind),
                                   dtype)
    if kind == "mla":
        return attn.mla_init_cache(batch, max_len, attn_config(cfg, kind),
                                   dtype)
    if kind == "ssd":
        return ssm_lib.mamba2_init_state(batch, ssm_config(cfg))
    if kind == "rglru":
        return ssm_lib.rglru_init_state(batch, rglru_config(cfg))
    raise ValueError(kind)


def _layer_prefill(p, x, positions, cfg: ArchConfig, kind: str,
                   ffn_kind: str, max_len: int):
    h = L.rmsnorm(p["mixer_norm"], x, cfg.rms_eps)
    if kind in ("attn", "local_attn"):
        h, cache = attn.gqa_prefill_cache(p["mixer"], h, positions,
                                          attn_config(cfg, kind), max_len)
    elif kind == "mla":
        h, cache = attn.mla_prefill_cache(p["mixer"], h, positions,
                                          attn_config(cfg, kind), max_len)
    elif kind == "ssd":
        h, cache = ssm_lib.mamba2_forward(p["mixer"], h, ssm_config(cfg),
                                          return_state=True)
    elif kind == "rglru":
        h, cache = ssm_lib.rglru_block_forward(p["mixer"], h,
                                               rglru_config(cfg),
                                               return_state=True)
    x = x + h
    x, aux = _ffn_apply(p, x, cfg, ffn_kind)
    return x, aux, cache


def _layer_decode(p, x, pos, cache, cfg: ArchConfig, kind: str,
                  ffn_kind: str):
    h = L.rmsnorm(p["mixer_norm"], x, cfg.rms_eps)
    if kind in ("attn", "local_attn"):
        h, cache = attn.gqa_decode_step(p["mixer"], h, pos, cache,
                                        attn_config(cfg, kind))
    elif kind == "mla":
        h, cache = attn.mla_decode_step(p["mixer"], h, pos, cache,
                                        attn_config(cfg, kind))
    elif kind == "ssd":
        h, cache = ssm_lib.mamba2_decode_step(p["mixer"], h, cache,
                                              ssm_config(cfg))
    elif kind == "rglru":
        h, cache = ssm_lib.rglru_block_forward(p["mixer"], h,
                                               rglru_config(cfg), state=cache,
                                               return_state=True)
    x = x + h
    x, _ = _ffn_apply(p, x, cfg, ffn_kind)
    return x, cache


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ArchConfig, dtype=jnp.float32):
    prefix, reps, suffix, kinds = _layer_plan(cfg)
    period = len(cfg.block_pattern)
    keys = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    if cfg.embed_inputs:
        params["embed"] = L.embedding_init(keys[0], cfg.vocab_size,
                                           cfg.d_model, dtype)
    params["final_norm"] = L.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab_size,
                                         dtype)

    def init_one(k, li):
        kind = kinds[li]
        return _layer_init(k, cfg, kind, _ffn_kind(cfg, li, kind), dtype)

    if prefix:
        pk = jax.random.split(keys[2], len(prefix))
        params["prefix"] = {str(i): init_one(pk[i], li)
                            for i, li in enumerate(prefix)}
    if reps:
        base = len(prefix)

        def init_group(k):
            gk = jax.random.split(k, period)
            return {str(j): _layer_init(
                gk[j], cfg, kinds[base + j],
                _ffn_kind(cfg, base + j, kinds[base + j]), dtype)
                for j in range(period)}

        gkeys = jax.random.split(keys[3], reps)
        params["groups"] = jax.vmap(init_group)(gkeys)
    if suffix:
        sk = jax.random.split(jax.random.fold_in(key, 99), len(suffix))
        params["suffix"] = {str(i): init_one(sk[i], li)
                            for i, li in enumerate(suffix)}
    return params


def init_abstract(cfg: ArchConfig, dtype=jnp.float32):
    """ShapeDtypeStruct pytree of params (no allocation) for the dry-run."""
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg, dtype=dtype),
        jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# forward (train) — logits + aux losses
# ---------------------------------------------------------------------------
def _embed_in(params, cfg, tokens=None, embeds=None):
    if cfg.embed_inputs:
        assert tokens is not None
        x = L.embed(params["embed"], tokens)
    else:
        assert embeds is not None
        x = embeds.astype(jnp.bfloat16)
    return aconstraint(x, ("batch", "seq", "embed"))


def _head(params, cfg, x):
    x = L.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = L.dense(params["lm_head"], x).astype(jnp.float32)
    if cfg.logit_soft_cap:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return aconstraint(logits, ("batch", "seq", "vocab"))


def forward(params, cfg: ArchConfig, tokens=None, embeds=None,
            positions=None, remat: str = "none"):
    """-> (logits (B,S,V) fp32, aux scalar)."""
    prefix, reps, suffix, kinds = _layer_plan(cfg)
    period = len(cfg.block_pattern)
    x = _embed_in(params, cfg, tokens, embeds)
    s = x.shape[1]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    aux = jnp.zeros((), jnp.float32)

    def apply_layer(p, x, li):
        kind = kinds[li]
        return _layer_forward(p, x, positions, cfg, kind,
                              _ffn_kind(cfg, li, kind))

    for i, li in enumerate(prefix):
        x, a = apply_layer(params["prefix"][str(i)], x, li)
        aux += a
    if reps:
        base = len(prefix)

        def group_fn(x, gp):
            a_tot = jnp.zeros((), jnp.float32)
            for j in range(period):
                x, a = _layer_forward(
                    gp[str(j)], x, positions, cfg, kinds[base + j],
                    _ffn_kind(cfg, base + j, kinds[base + j]))
                a_tot += a
            return x, a_tot

        group_fn = _maybe_remat(group_fn, remat)

        def scan_body(carry, gp):
            x, aux = carry
            x, a = group_fn(x, gp)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(scan_body, (x, aux), params["groups"])
    for i, li in enumerate(suffix):
        x, a = apply_layer(params["suffix"][str(i)], x, li)
        aux += a
    return _head(params, cfg, x), aux


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(remat)


def loss_fn(params, cfg: ArchConfig, batch, remat: str = "none"):
    """batch: {"tokens"|"embeds", "labels", optional "mask"} -> (loss, metrics).

    Cross-entropy is computed tensor-parallel-friendly: logits stay sharded
    over the vocab axis; the label logit is extracted by a masked reduction
    (fuses to a local select+sum, GSPMD adds a tiny psum) instead of
    take_along_axis, which would force an all-gather of the full fp32
    logits (~40 GB/device at 151936-vocab train shapes — observed before
    this fix)."""
    logits, aux = forward(params, cfg,
                          tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"), remat=remat)
    labels = batch["labels"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)          # (B,S)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    nll = lse - label_logit
    mask = batch.get("mask")
    if mask is None:
        loss = jnp.mean(nll)
    else:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + aux
    return total, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    prefix, reps, suffix, kinds = _layer_plan(cfg)
    period = len(cfg.block_pattern)
    cache: dict[str, Any] = {}
    if prefix:
        cache["prefix"] = {str(i): _layer_cache_init(cfg, kinds[li], batch,
                                                     max_len, dtype)
                           for i, li in enumerate(prefix)}
    if reps:
        base = len(prefix)

        def one_group():
            return {str(j): _layer_cache_init(cfg, kinds[base + j], batch,
                                              max_len, dtype)
                    for j in range(period)}

        cache["groups"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (reps,) + x.shape), one_group())
    if suffix:
        first = cfg.n_layers - len(suffix)
        cache["suffix"] = {str(i): _layer_cache_init(cfg, kinds[first + i],
                                                     batch, max_len, dtype)
                           for i in range(len(suffix))}
    return cache


def prefill(params, cfg: ArchConfig, tokens=None, embeds=None,
            max_len: int | None = None, remat: str = "none"):
    """Run the prompt; -> (last-position logits (B,V), cache at len S)."""
    prefix, reps, suffix, kinds = _layer_plan(cfg)
    period = len(cfg.block_pattern)
    x = _embed_in(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    max_len = max_len or s
    positions = jnp.arange(s, dtype=jnp.int32)
    caches: dict[str, Any] = {}

    for i, li in enumerate(prefix):
        x, _, c = _layer_prefill(params["prefix"][str(i)], x, positions, cfg,
                                 kinds[li], _ffn_kind(cfg, li, kinds[li]),
                                 max_len)
        caches.setdefault("prefix", {})[str(i)] = c
    if reps:
        base = len(prefix)

        def group_fn(x, gp):
            cs = {}
            for j in range(period):
                x, _, c = _layer_prefill(
                    gp[str(j)], x, positions, cfg, kinds[base + j],
                    _ffn_kind(cfg, base + j, kinds[base + j]), max_len)
                cs[str(j)] = c
            return x, cs

        group_fn = _maybe_remat(group_fn, remat)

        def scan_body(x, gp):
            return group_fn(x, gp)

        x, gcaches = jax.lax.scan(scan_body, x, params["groups"])
        caches["groups"] = gcaches
    for i, li in enumerate(suffix):
        x, _, c = _layer_prefill(params["suffix"][str(i)], x, positions, cfg,
                                 kinds[li], _ffn_kind(cfg, li, kinds[li]),
                                 max_len)
        caches.setdefault("suffix", {})[str(i)] = c
    logits = _head(params, cfg, x[:, -1:])[:, 0]
    return logits, caches


def decode_step(params, cfg: ArchConfig, pos, cache, token=None, embed=None):
    """One token for the whole batch at absolute position ``pos`` (scalar).

    token: (B,) int32 or embed: (B, D). -> (logits (B,V), new cache)."""
    prefix, reps, suffix, kinds = _layer_plan(cfg)
    period = len(cfg.block_pattern)
    if cfg.embed_inputs:
        x = L.embed(params["embed"], token[:, None])
    else:
        x = embed[:, None].astype(jnp.bfloat16)
    pos = jnp.asarray(pos, jnp.int32)
    new_cache: dict[str, Any] = {}

    for i, li in enumerate(prefix):
        x, c = _layer_decode(params["prefix"][str(i)], x, pos,
                             cache["prefix"][str(i)], cfg, kinds[li],
                             _ffn_kind(cfg, li, kinds[li]))
        new_cache.setdefault("prefix", {})[str(i)] = c
    if reps:
        base = len(prefix)

        def scan_body(x, gp_gc):
            gp, gc = gp_gc
            ncs = {}
            for j in range(period):
                x, c = _layer_decode(gp[str(j)], x, pos, gc[str(j)], cfg,
                                     kinds[base + j],
                                     _ffn_kind(cfg, base + j, kinds[base + j]))
                ncs[str(j)] = c
            return x, ncs

        x, gcaches = jax.lax.scan(scan_body, x,
                                  (params["groups"], cache["groups"]))
        new_cache["groups"] = gcaches
    for i, li in enumerate(suffix):
        first = cfg.n_layers - len(suffix)
        x, c = _layer_decode(params["suffix"][str(i)], x, pos,
                             cache["suffix"][str(i)], cfg, kinds[first + i],
                             _ffn_kind(cfg, first + i, kinds[first + i]))
        new_cache.setdefault("suffix", {})[str(i)] = c
    logits = _head(params, cfg, x)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# logical sharding rules (path regex -> logical axis names per dim)
# ---------------------------------------------------------------------------
PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/table$", ("vocab", "fsdp")),
    (r"lm_head/kernel$", ("fsdp", "vocab")),
    (r"mixer/wq/kernel$", ("fsdp", "heads")),
    (r"mixer/w[kv]/kernel$", ("fsdp", "kv_heads")),
    (r"mixer/wo/kernel$", ("heads", "fsdp")),
    (r"mixer/wq/bias$", ("heads",)),
    (r"mixer/w[kv]/bias$", ("kv_heads",)),
    (r"mixer/wdq/kernel$", ("fsdp", None)),
    (r"mixer/wuq/kernel$", (None, "heads")),
    (r"mixer/wdkv/kernel$", ("fsdp", None)),
    (r"mixer/wu[kv]/kernel$", (None, "heads")),
    (r"ffn/w[ig]/kernel$", ("fsdp", "mlp")),
    (r"ffn/wo/kernel$", ("mlp", "fsdp")),
    (r"ffn/shared/w[ig]/kernel$", ("fsdp", "mlp")),
    (r"ffn/shared/wo/kernel$", ("mlp", "fsdp")),
    (r"ffn/router/kernel$", ("fsdp", None)),
    (r"ffn/wi$", ("expert", "fsdp", "expert_mlp")),
    (r"ffn/wg$", ("expert", "fsdp", "expert_mlp")),
    (r"ffn/wo$", ("expert", "expert_mlp", "fsdp")),
    (r"mixer/in_proj/kernel$", ("fsdp", "mlp")),
    (r"mixer/out_proj/kernel$", ("mlp", "fsdp")),
    (r"mixer/w_gate/kernel$", ("fsdp", "mlp")),
    (r"mixer/w_rec_in/kernel$", ("fsdp", "mlp")),
    (r"mixer/w_[ai]/kernel$", (None, "mlp")),
    (r"mixer/w_[ai]/bias$", ("mlp",)),
    (r"mixer/w_out/kernel$", ("mlp", "fsdp")),
    (r"mixer/conv_w$", (None, "mlp")),
    (r"mixer/conv_b$", ("mlp",)),
    (r"mixer/lambda$", ("mlp",)),
    (r"mixer/(A_log|D|dt_bias)$", (None,)),
    (r".*(norm.*/scale|q_norm|k_norm)$", (None,)),
]


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def param_logical_axes(params_or_abstract):
    """Pytree of logical-name tuples parallel to params. Stacked group leaves
    get a leading None for the repeat dim."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_or_abstract)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        names = None
        for pat, nm in PARAM_RULES:
            if re.search(pat, ps):
                names = nm
                break
        ndim = len(leaf.shape)
        if names is None:
            names = (None,) * ndim
        if ps.startswith("groups/"):
            names = (None,) + tuple(names)
        names = tuple(names)[:ndim] + (None,) * max(0, ndim - len(names))
        out.append(names)
    return jax.tree_util.tree_unflatten(treedef, out)


def cache_logical_axes(cache):
    """Batch dim -> ("batch",); kv-head dim of attention caches -> model."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        ndim = len(leaf.shape)
        stacked = ps.startswith("groups/")
        core = ndim - (1 if stacked else 0)
        if ps.endswith("/pos"):
            names: tuple = (None,) * core
        elif ps.endswith("/k") or ps.endswith("/v"):
            # kv_heads first; when it cannot shard (kv < TP), the sequence
            # dim picks up the model axis instead (axis dedupe in
            # param_sharding keeps them mutually exclusive)
            names = ("batch", "kv_seq", "kv_heads", None)[:core]
        elif ps.endswith("_scale"):
            names = ("batch", "kv_seq", "kv_heads")[:core]
        elif ps.endswith("/c") or ps.endswith("/k_rope"):
            names = ("batch", "kv_seq", None)[:core]
        else:  # ssm/conv states
            names = ("batch",) + (None,) * (core - 1)
        if stacked:
            names = (None,) + names
        out.append(names)
    return jax.tree_util.tree_unflatten(treedef, out)
