"""Attention variants: GQA/MQA/MHA (full, query-blocked, local-window) and
DeepSeek-style MLA (multi-head latent attention), with KV caches for decode.

Implementation notes
  * Scores/softmax in fp32; einsum operands bf16 (MXU) unless configured.
  * ``blocked`` attention scans over query blocks with exact per-row softmax
    against the full K — memory O(q_block × S_kv) instead of O(S²) — the
    XLA-level equivalent of memory-efficient attention (Rabe & Staats). The
    dry-run/roofline path uses it for the 32k shapes.
  * Local attention uses a ring KV cache of size ``window`` during decode —
    this is what makes recurrentgemma's `long_500k` cell O(window), not O(S).
  * MLA decode uses the weight-absorption trick: queries are projected into
    the compressed latent space so the cache stays (r_kv + d_rope) per token.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    window: int = 0             # 0 => global causal
    q_block: int = 0            # 0 => unblocked (full scores)
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    rms_eps: float = 1e-5
    kv_quant: bool = False      # int8 KV cache (per-vector scales)


# ---------------------------------------------------------------------------
# GQA family
# ---------------------------------------------------------------------------
def gqa_init(key, cfg: AttnConfig, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(kq, cfg.d_model, cfg.n_heads * cfg.d_head, dtype,
                           bias=cfg.qkv_bias),
        "wk": L.dense_init(kk, cfg.d_model, cfg.n_kv_heads * cfg.d_head, dtype,
                           bias=cfg.qkv_bias),
        "wv": L.dense_init(kv, cfg.d_model, cfg.n_kv_heads * cfg.d_head, dtype,
                           bias=cfg.qkv_bias),
        "wo": L.dense_init(ko, cfg.n_heads * cfg.d_head, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.d_head,), dtype)
        p["k_norm"] = jnp.ones((cfg.d_head,), dtype)
    return p


def _project_qkv(p, x, cfg: AttnConfig, positions):
    b, s, _ = x.shape
    q = L.dense(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = L.dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = L.dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = L.rms_head_norm(p["q_norm"], q, cfg.rms_eps)
        k = L.rms_head_norm(p["k_norm"], k, cfg.rms_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    from repro.launch.partition import aconstraint
    q = aconstraint(q, ("batch", "seq", "heads", None))
    k = aconstraint(k, ("batch", "seq", "kv_heads", None))
    v = aconstraint(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _sdpa(q, k, v, q_pos, kv_pos, *, window: int, scale: float):
    """q: (B,Sq,H,dh); k,v: (B,Skv,Hkv,dh); positions broadcastable (Sq,)/(Skv,).

    Causal (+ optional local-window) grouped attention. kv_pos < 0 marks
    invalid (unwritten ring) slots.
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    mask = kv_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= kv_pos[None, :] > (q_pos[:, None] - window)
    mask &= kv_pos[None, :] >= 0
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, dh)


def gqa_forward(p, x, positions, cfg: AttnConfig):
    """Training/prefill forward (no cache). positions: (S,).

    KV heads are repeated up to the full head count (Megatron-style
    repeat-KV): the plain "bqhd,bkhd" einsum then shards cleanly on the
    head axis even when n_kv_heads < TP degree — the grouped
    (hkv, g)-reshape variant breaks GSPMD head sharding (observed: fully
    replicated 34 GB score tensors on llama3-405b). Decode keeps the
    grouped path: repeating a 32k-entry cache would be madness."""
    from repro.launch.partition import aconstraint
    q, k, v = _project_qkv(p, x, cfg, positions)
    g = cfg.n_heads // cfg.n_kv_heads
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = aconstraint(k, ("batch", "seq", "heads", None))
        v = aconstraint(v, ("batch", "seq", "heads", None))
    scale = cfg.d_head ** -0.5
    if cfg.q_block and x.shape[1] > cfg.q_block and x.shape[1] % cfg.q_block == 0:
        nb = x.shape[1] // cfg.q_block
        qb = q.reshape(x.shape[0], nb, cfg.q_block, cfg.n_heads, cfg.d_head)
        pb = positions.reshape(nb, cfg.q_block)

        def step(_, blk):
            qblk, posblk = blk
            o = _sdpa(qblk, k, v, posblk, positions, window=cfg.window,
                      scale=scale)
            return None, o

        _, out = jax.lax.scan(step, None, (qb.swapaxes(0, 1),
                                           pb))
        out = out.swapaxes(0, 1).reshape(x.shape[0], x.shape[1], -1)
    else:
        out = _sdpa(q, k, v, positions, positions, window=cfg.window,
                    scale=scale).reshape(x.shape[0], x.shape[1], -1)
    return L.dense(p["wo"], out)


def _kv_quantize(x):
    """(..., d_head) -> (int8 values, fp16-range scales (...,)). Per-vector
    absmax scaling (KIVI/KVQuant-style per-token-per-head granularity)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _kv_dequantize(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def gqa_init_cache(batch: int, max_len: int, cfg: AttnConfig,
                   dtype=jnp.bfloat16):
    size = min(cfg.window, max_len) if cfg.window else max_len
    shape = (batch, size, cfg.n_kv_heads, cfg.d_head)
    if cfg.kv_quant:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
            "v_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
            "pos": jnp.full((size,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        # per-slot absolute position; -1 == never written (ring validity)
        "pos": jnp.full((size,), -1, jnp.int32),
    }


def gqa_prefill_cache(p, x, positions, cfg: AttnConfig, max_len: int):
    """Run prefill and return (output, cache populated with S entries)."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = gqa_forward(p, x, positions, cfg)  # recomputes qkv; acceptable: XLA CSEs
    size = min(cfg.window, max_len) if cfg.window else max_len
    s = x.shape[1]
    cache = gqa_init_cache(x.shape[0], max_len, cfg, k.dtype)
    if cfg.kv_quant:
        (k, k_sc), (v, v_sc) = _kv_quantize(k), _kv_quantize(v)
    if cfg.window:
        # Ring invariant: position p lives at slot p % size — decode writes
        # with the same rule, so prefill must scatter accordingly.
        if s > size:
            k, v, positions = k[:, -size:], v[:, -size:], positions[-size:]
            if cfg.kv_quant:
                k_sc, v_sc = k_sc[:, -size:], v_sc[:, -size:]
        slots = jnp.mod(positions.astype(jnp.int32), size)
        cache["k"] = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
        if cfg.kv_quant:
            cache["k_scale"] = cache["k_scale"].at[:, slots].set(k_sc)
            cache["v_scale"] = cache["v_scale"].at[:, slots].set(v_sc)
        cache["pos"] = cache["pos"].at[slots].set(positions.astype(jnp.int32))
    else:
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
        if cfg.kv_quant:
            cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], k_sc, 0, axis=1)
            cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], v_sc, 0, axis=1)
        cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.astype(jnp.int32), 0, axis=0)
    return out, cache


def gqa_decode_step(p, x, pos, cache, cfg: AttnConfig):
    """x: (B,1,D); pos: scalar int32 absolute position. Returns (out, cache)."""
    positions = pos[None].astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    size = cache["k"].shape[1]
    slot = (pos % size).astype(jnp.int32) if cfg.window else pos.astype(jnp.int32)
    cache = dict(cache)
    if cfg.kv_quant:
        (kq, k_sc), (vq, v_sc) = _kv_quantize(k), _kv_quantize(v)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], kq, slot, axis=1)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], vq, slot, axis=1)
        cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], k_sc, slot, axis=1)
        cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], v_sc, slot, axis=1)
        k_full = _kv_dequantize(cache["k"], cache["k_scale"], k.dtype)
        v_full = _kv_dequantize(cache["v"], cache["v_scale"], v.dtype)
    else:
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        k_full, v_full = cache["k"], cache["v"]
    cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions, slot, axis=0)
    out = _sdpa(q, k_full, v_full, positions, cache["pos"],
                window=cfg.window, scale=cfg.d_head ** -0.5)
    return L.dense(p["wo"], out.reshape(x.shape[0], 1, -1)), cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 / MiniCPM3 style)
# ---------------------------------------------------------------------------
def mla_init(key, cfg: AttnConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    dqk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p = {
        "wdq": L.dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype),
        "q_norm": L.rmsnorm_init(cfg.q_lora_rank, dtype),
        "wuq": L.dense_init(ks[1], cfg.q_lora_rank, cfg.n_heads * dqk, dtype),
        # fused kv-down + rope-k projection, DeepSeek layout
        "wdkv": L.dense_init(ks[2], cfg.d_model,
                             cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype),
        "kv_norm": L.rmsnorm_init(cfg.kv_lora_rank, dtype),
        "wuk": L.dense_init(ks[3], cfg.kv_lora_rank,
                            cfg.n_heads * cfg.qk_nope_head_dim, dtype),
        "wuv": L.dense_init(ks[4], cfg.kv_lora_rank,
                            cfg.n_heads * cfg.v_head_dim, dtype),
        "wo": L.dense_init(ks[5], cfg.n_heads * cfg.v_head_dim, cfg.d_model,
                           dtype),
    }
    return p


def _mla_q(p, x, positions, cfg: AttnConfig):
    b, s, _ = x.shape
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = L.rmsnorm(p["q_norm"], L.dense(p["wdq"], x), cfg.rms_eps)
    q = L.dense(p["wuq"], cq).reshape(b, s, cfg.n_heads, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, positions, cfg: AttnConfig):
    dr = cfg.qk_rope_head_dim
    ckv = L.dense(p["wdkv"], x)
    c, k_rope = ckv[..., :cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c = L.rmsnorm(p["kv_norm"], c, cfg.rms_eps)
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta,
                          has_head_dim=False)           # (B,S,dr) shared
    return c, k_rope


def _mla_sdpa(q_nope, q_rope, k_nope, k_rope, v, q_pos, kv_pos, scale):
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32))
              + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    mask = kv_pos[None, :] <= q_pos[:, None]
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def mla_forward(p, x, positions, cfg: AttnConfig):
    """Training/prefill: decompress K/V (standard path). Honors
    cfg.q_block (query-blocked exact attention, bounded score memory)."""
    b, s, _ = x.shape
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    c, k_rope = _mla_latent(p, x, positions, cfg)
    k_nope = L.dense(p["wuk"], c).reshape(b, s, cfg.n_heads, dn)
    v = L.dense(p["wuv"], c).reshape(b, s, cfg.n_heads, dv)
    scale = (dn + dr) ** -0.5
    qb = cfg.q_block
    if qb and s > qb and s % qb == 0:
        nb = s // qb

        def step(_, blk):
            qn, qr, posblk = blk
            return None, _mla_sdpa(qn, qr, k_nope, k_rope, v, posblk,
                                   positions, scale)

        _, out = jax.lax.scan(
            step, None,
            (q_nope.reshape(b, nb, qb, cfg.n_heads, dn).swapaxes(0, 1),
             q_rope.reshape(b, nb, qb, cfg.n_heads, dr).swapaxes(0, 1),
             positions.reshape(nb, qb)))
        out = out.swapaxes(0, 1).reshape(b, s, -1)
    else:
        out = _mla_sdpa(q_nope, q_rope, k_nope, k_rope, v, positions,
                        positions, scale).reshape(b, s, -1)
    return L.dense(p["wo"], out)


def mla_init_cache(batch: int, max_len: int, cfg: AttnConfig,
                   dtype=jnp.bfloat16):
    return {
        "c": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
        "pos": jnp.full((max_len,), -1, jnp.int32),
    }


def mla_prefill_cache(p, x, positions, cfg: AttnConfig, max_len: int):
    out = mla_forward(p, x, positions, cfg)
    c, k_rope = _mla_latent(p, x, positions, cfg)
    cache = mla_init_cache(x.shape[0], max_len, cfg, c.dtype)
    cache["c"] = jax.lax.dynamic_update_slice_in_dim(
        cache["c"], c.astype(cache["c"].dtype), 0, axis=1)
    cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), 0, axis=1)
    cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions.astype(jnp.int32), 0, axis=0)
    return out, cache


def mla_decode_step(p, x, pos, cache, cfg: AttnConfig):
    """Weight-absorbed MLA decode: scores/outputs computed in latent space;
    per-token cache cost is kv_lora_rank + qk_rope_head_dim."""
    b = x.shape[0]
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = pos[None].astype(jnp.int32)
    q_nope, q_rope = _mla_q(p, x, positions, cfg)           # (B,1,H,*)
    c_new, k_rope_new = _mla_latent(p, x, positions, cfg)   # (B,1,r),(B,1,dr)
    cache = dict(cache)
    cache["c"] = jax.lax.dynamic_update_slice_in_dim(
        cache["c"], c_new.astype(cache["c"].dtype), pos, axis=1)
    cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), pos, axis=1)
    cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions, pos, axis=0)
    # absorb W_uk into q: q_lat[b,h,r] = Σ_d q_nope[b,h,d] wuk[r, h*dn+d]
    wuk = p["wuk"]["kernel"].reshape(cfg.kv_lora_rank, cfg.n_heads, dn)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scale = (dn + dr) ** -0.5
    scores = (jnp.einsum("bqhr,bkr->bhqk", q_lat,
                         cache["c"].astype(jnp.float32))
              + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                           cache["k_rope"].astype(jnp.float32))) * scale
    mask = (cache["pos"][None, :] <= positions[:, None]) & (cache["pos"][None, :] >= 0)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhqk,bkr->bqhr", probs,
                         cache["c"].astype(jnp.float32))    # (B,1,H,r)
    wuv = p["wuv"]["kernel"].reshape(cfg.kv_lora_rank, cfg.n_heads, dv)
    out = jnp.einsum("bqhr,rhd->bqhd", out_lat, wuv.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(b, 1, -1)
    return L.dense(p["wo"], out), cache
