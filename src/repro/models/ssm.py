"""State-space mixers: Mamba-2 (SSD, chunked) and Griffin's RG-LRU.

Mamba-2 / SSD (arXiv:2405.21060): the chunked "state-space duality"
algorithm — intra-chunk quadratic (attention-like, MXU-friendly) +
inter-chunk linear recurrence over chunk states. Matches the naive
sequential recurrence exactly (tests/test_ssm.py) while exposing matmul
parallelism; chunk size is the TPU analogue of the paper's block tiling.

RG-LRU (Griffin, arXiv:2402.19427): gated linear recurrence
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t),
    a_t = exp(-c · softplus(Λ) · r_t)
computed with an associative scan over the sequence (log-depth on TPU).

Both provide O(1)-state decode steps — this is why the `long_500k` cell is
runnable for these families only.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    expand: int = 2
    headdim: int = 64
    chunk: int = 256
    conv_width: int = 4

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim


def mamba2_init(key, cfg: SSMConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d_in = cfg.d_inner
    conv_dim = d_in + 2 * cfg.d_state
    return {
        # fused input projection: [z | x | B | C | dt]
        "in_proj": L.dense_init(ks[0], cfg.d_model,
                                2 * d_in + 2 * cfg.d_state + cfg.n_heads,
                                dtype),
        "conv_w": jax.nn.initializers.normal(0.1)(
            ks[1], (cfg.conv_width, conv_dim), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, cfg.n_heads + 1, dtype=dtype)),
        "D": jnp.ones((cfg.n_heads,), dtype),
        "dt_bias": jnp.zeros((cfg.n_heads,), dtype),
        "norm": L.rmsnorm_init(d_in, dtype),
        "out_proj": L.dense_init(ks[3], d_in, cfg.d_model, dtype),
    }


def _split_proj(p, u, cfg: SSMConfig):
    d_in, ds, nh = cfg.d_inner, cfg.d_state, cfg.n_heads
    zxbcdt = L.dense(p["in_proj"], u)
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + d_in + 2 * ds]
    dt = zxbcdt[..., -nh:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b, state=None):
    """Depthwise causal conv1d, width W. xBC: (B,S,C); conv_w: (W,C).

    If ``state`` ((B, W-1, C), previous inputs) is given, runs in streaming
    mode and returns (out, new_state)."""
    w = conv_w.shape[0]
    if state is not None:
        ctx = jnp.concatenate([state.astype(xBC.dtype), xBC], axis=1)
        new_state = ctx[:, -(w - 1):]
    else:
        ctx = jnp.pad(xBC, ((0, 0), (w - 1, 0), (0, 0)))
        new_state = ctx[:, -(w - 1):]
    out = sum(ctx[:, i:i + xBC.shape[1]] * conv_w[i].astype(xBC.dtype)
              for i in range(w))
    out = jax.nn.silu(out + conv_b.astype(xBC.dtype))
    return out, new_state


def _segsum(x):
    """x: (..., Q) log-decays -> (..., Q, Q) lower-triangular cumulative sums:
    out[i,j] = sum_{k=j+1..i} x[k] for i >= j, -inf otherwise."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """SSD forward. Shapes: x (b,s,h,p); dt (b,s,h) [post-softplus];
    A (h,) [negative]; Bm, Cm (b,s,n). Returns (y (b,s,h,p), final_state
    (b,h,p,n))."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    # dt-scaled input & per-step log decay
    xd = x * dt[..., None]                                 # (b,s,h,p)
    dA = dt * A[None, None, :]                             # (b,s,h) log-decay
    # chunk views
    xc = xd.reshape(b, nc, chunk, h, p)
    dAc = dA.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)
    dA_cs = jnp.cumsum(dAc, axis=2)                        # (b,nc,Q,h)

    # 1) intra-chunk (quadratic, MXU): Y_diag[l] = Σ_{s<=l} C_l·B_s decay x_s
    Lmat = jnp.exp(_segsum(dAc.transpose(0, 1, 3, 2)))     # (b,nc,h,Q,Q)
    CB = jnp.einsum("bcln,bcsn->bcls", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))                # (b,nc,Q,Q)
    W = Lmat * CB[:, :, None]                              # (b,nc,h,Q,Q)
    Y_diag = jnp.einsum("bchls,bcshp->bclhp", W, xc.astype(jnp.float32))

    # 2) per-chunk output states: contribution of this chunk to the carried
    # state: states[c] = Σ_l B_l ⊗ x_l · exp(dA_sum - dA_cs[l])
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)    # (b,nc,Q,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn",
                        Bc.astype(jnp.float32), decay_states,
                        xc.astype(jnp.float32))            # (b,nc,h,p,n)

    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])              # (b,nc,h)
    init = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp                                       # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                   # emit state *before* chunk

    final_state, prev_states = jax.lax.scan(
        step, init, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                # (b,nc,h,p,n)

    # 4) state -> output within chunk: Y_off[l] = C_l · prev_state · exp(dA_cs[l])
    state_decay = jnp.exp(dA_cs)                            # (b,nc,Q,h)
    Y_off = jnp.einsum("bcln,bchpn,bclh->bclhp",
                       Cc.astype(jnp.float32), prev_states, state_decay)

    y = (Y_diag + Y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def mamba2_forward(p, u, cfg: SSMConfig, initial_state=None,
                   conv_state=None, return_state: bool = False):
    """u: (B,S,D) -> (B,S,D). Optionally returns (out, (conv_state, ssm_state))."""
    b, s, _ = u.shape
    d_in, ds, nh, hp = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.headdim
    z, xBC, dt = _split_proj(p, u, cfg)
    xBC, new_conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    x = xBC[..., :d_in].reshape(b, s, nh, hp)
    Bm = xBC[..., d_in:d_in + ds]
    Cm = xBC[..., d_in + ds:]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    # Pad S up to a chunk multiple with dt=0 no-op steps: dA=exp(0)=1 keeps
    # the carried state untouched and x̄=x·dt=0 injects nothing, so outputs
    # and final_state are exact.
    pad = (-s) % cfg.chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, final_state = ssd_chunked(x, dt, A, Bm, Cm, cfg.chunk, initial_state)
    if pad:
        y = y[:, :s]
        x = x[:, :s]
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * x
    y = y.reshape(b, s, d_in)
    # gated RMSNorm (Mamba-2): norm(y * silu(z))
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = L.dense(p["out_proj"], y)
    if return_state:
        return out, (new_conv_state, final_state)
    return out


def mamba2_init_state(batch: int, cfg: SSMConfig, dtype=jnp.float32):
    conv_dim = cfg.d_inner + 2 * cfg.d_state
    return (jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
            jnp.zeros((batch, cfg.n_heads, cfg.headdim, cfg.d_state), dtype))


def mamba2_decode_step(p, u, state, cfg: SSMConfig):
    """u: (B,1,D); state from mamba2_init_state. O(1) per token."""
    conv_state, h = state
    b = u.shape[0]
    d_in, ds, nh, hp = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.headdim
    z, xBC, dt = _split_proj(p, u, cfg)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    x = xBC[:, 0, :d_in].reshape(b, nh, hp)
    Bm = xBC[:, 0, d_in:d_in + ds]
    Cm = xBC[:, 0, d_in + ds:]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))   # (B,h)
    dA = jnp.exp(dt1 * A[None, :])                              # (B,h)
    # h' = h * dA + dt·x ⊗ B
    xd = x.astype(jnp.float32) * dt1[..., None]
    h = h.astype(jnp.float32) * dA[..., None, None] + \
        jnp.einsum("bhp,bn->bhpn", xd, Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, 1, d_in).astype(u.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return L.dense(p["out_proj"], y), (conv_state, h)


def ssd_naive(x, dt, A, Bm, Cm, initial_state=None):
    """Sequential reference recurrence for tests: O(S) scan over tokens."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    init = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))

    def step(hstate, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt * A[None, :])                     # (b,h)
        hstate = hstate * dA[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt.astype(jnp.float32) * dtt[..., None],
            Bt.astype(jnp.float32))
        y = jnp.einsum("bhpn,bn->bhp", hstate, Ct.astype(jnp.float32))
        return hstate, y

    final, ys = jax.lax.scan(
        step, init, (x.swapaxes(0, 1), dt.swapaxes(0, 1),
                     Bm.swapaxes(0, 1), Cm.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), final


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / recurrentgemma)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    lru_width: int
    conv_width: int = 4
    c: float = 8.0


def rglru_block_init(key, cfg: RGLRUConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    w = cfg.lru_width
    # Λ init so that a ∈ (0.9, 0.999) at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[4], (w,), dtype, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * cfg.c))).astype(dtype)
    return {
        "w_gate": L.dense_init(ks[0], cfg.d_model, w, dtype),
        "w_rec_in": L.dense_init(ks[1], cfg.d_model, w, dtype),
        "conv_w": jax.nn.initializers.normal(0.1)(ks[2], (cfg.conv_width, w),
                                                  dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": L.dense_init(ks[3], w, w, dtype, bias=True),
        "w_i": L.dense_init(ks[5], w, w, dtype, bias=True),
        "lambda": lam,
        "w_out": L.dense_init(jax.random.fold_in(key, 7), w, cfg.d_model,
                              dtype),
    }


def _rglru_core(p, x, cfg: RGLRUConfig, h0=None):
    """x: (B,S,W) post-conv activations. Returns (h_seq, h_last)."""
    r = jax.nn.sigmoid(L.dense(p["w_a"], x, jnp.float32))
    i = jax.nn.sigmoid(L.dense(p["w_i"], x, jnp.float32))
    log_a = -cfg.c * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_x = x.astype(jnp.float32) * i
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    a_scan, b_scan = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = b_scan if h0 is None else b_scan + a_scan * h0[:, None, :]
    return h.astype(x.dtype), h[:, -1]


def rglru_block_forward(p, u, cfg: RGLRUConfig, state=None,
                        return_state: bool = False):
    """Griffin recurrent block: gate ⊙ RG-LRU(conv(W_in u)), then W_out.

    state: (conv_state (B,W-1,w), h (B,w)) or None."""
    conv_state, h0 = state if state is not None else (None, None)
    gate = jax.nn.gelu(L.dense(p["w_gate"], u))
    rec = L.dense(p["w_rec_in"], u)
    rec, new_conv_state = _causal_conv(rec, p["conv_w"], p["conv_b"], conv_state)
    h, h_last = _rglru_core(p, rec, cfg, h0)
    out = L.dense(p["w_out"], gate * h)
    if return_state:
        return out, (new_conv_state, h_last.astype(jnp.float32))
    return out


def rglru_init_state(batch: int, cfg: RGLRUConfig, dtype=jnp.float32):
    return (jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
            jnp.zeros((batch, cfg.lru_width), jnp.float32))
