# FPPS reproduction — tier-1 verify + bench smoke in one command.
#
#   make check       fast suite (slow-marked tests excluded) + bench smoke
#   make test        fast test suite (default dev loop)
#   make test-all    full tier-1 suite, including slow subprocess tests
#   make bench       full benchmark harness (writes BENCH_*.json)
#   make bench-smoke every benchmark entry point in smoke mode
#
# pytest picks up pythonpath/markers from pyproject.toml; PYTHONPATH is
# still exported so `python -m benchmarks.run` resolves `repro` too.

export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test test-all bench bench-smoke

check: test bench-smoke

test:
	python -m pytest -q -m "not slow"

test-all:
	python -m pytest -q

bench:
	python -m benchmarks.run

bench-smoke:
	python -m benchmarks.run --quick
