# FPPS reproduction — tier-1 verify + bench smoke in one command.
#
#   make check       fast suite (slow-marked tests excluded) + bench smoke
#   make test        fast test suite (default dev loop)
#   make test-all    full tier-1 suite, including slow subprocess tests
#   make lint        ruff (pyproject [tool.ruff]); stdlib fallback offline
#   make bench       full benchmark harness (writes BENCH_*.json)
#   make bench-smoke every benchmark entry point in smoke mode
#   make bench-guard re-run quick sweeps, fail on >20% metric regression
#
# pytest picks up pythonpath/markers from pyproject.toml; PYTHONPATH is
# still exported so `python -m benchmarks.run` resolves `repro` too.

export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test test-all lint bench bench-smoke bench-guard

check: lint test bench-smoke

test:
	python -m pytest -q -m "not slow"

test-all:
	python -m pytest -q

lint:
	python tools/lint.py

bench:
	python -m benchmarks.run

bench-smoke:
	python -m benchmarks.run --quick

bench-guard:
	python -m benchmarks.check_regression
