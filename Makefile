# FPPS reproduction — tier-1 verify + bench smoke in one command.
#
#   make check       fast suite (slow-marked tests excluded) + bench smoke
#   make test        fast test suite (default dev loop; slow/chaos excluded)
#   make test-chaos  fault-injection chaos streams (marker: chaos)
#   make test-multidevice  sharded fleet on a forced 8-device host platform
#   make test-all    full tier-1 suite, including slow + chaos tests
#   make lint        ruff (pyproject [tool.ruff]); stdlib fallback offline;
#                    plus docstring coverage and tools/tracecheck.py
#   make tracecheck  trace-safety & kernel-contract static analysis only
#   make bench       full benchmark harness (writes BENCH_*.json)
#   make bench-smoke every benchmark entry point in smoke mode
#   make bench-guard re-run quick sweeps, fail on >20% metric regression
#
# pytest picks up pythonpath/markers from pyproject.toml; PYTHONPATH is
# still exported so `python -m benchmarks.run` resolves `repro` too.

export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test test-chaos test-multidevice test-all lint tracecheck \
        bench bench-smoke bench-guard

check: lint test bench-smoke

test:
	python -m pytest -q -m "not slow and not chaos"

test-chaos:
	python -m pytest -q -m chaos

# The worker subprocess forces XLA_FLAGS itself; the bench smoke respawns
# itself the same way (see benchmarks/device_sweep.py __main__ guard).
test-multidevice:
	python -m pytest -q -m multidevice
	python -m benchmarks.device_sweep --quick

test-all:
	python -m pytest -q

lint:
	python tools/lint.py
	python tools/tracecheck.py

tracecheck:
	python tools/tracecheck.py

bench:
	python -m benchmarks.run

bench-smoke:
	python -m benchmarks.run --quick

bench-guard:
	python -m benchmarks.check_regression
